"""AdamW + LR schedules, from scratch (no optax dependency).

The optimizer is a (init, update) pair over arbitrary pytrees, mirroring
the optax GradientTransformation contract so the SRR gradient-scaling
transform (:mod:`repro.optim.transforms`) composes in front of it. State
arrays inherit the parameter shardings under pjit (same tree structure).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Any          # first moment, like params
    nu: Any          # second moment, like params


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    # weight decay mask: params with path names in this set are excluded
    decay_exclude: Tuple[str, ...] = ("g", "b")

    def init(self, params: Any) -> AdamState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def _lr(self, step: jax.Array) -> jax.Array:
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads: Any, state: AdamState,
               params: Any) -> tuple[Any, AdamState]:
        """Returns (updates, new_state); apply with apply_updates."""
        step = state.step + 1
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def moments(g, m, v):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            return m2, v2

        # plain-tuple leaves (NamedTuples like AdapterParams stay nodes)
        is_pair = lambda t: type(t) is tuple
        mv = jax.tree_util.tree_map(moments, grads, state.mu, state.nu)
        mu = jax.tree_util.tree_map(lambda t: t[0], mv, is_leaf=is_pair)
        nu = jax.tree_util.tree_map(lambda t: t[1], mv, is_leaf=is_pair)

        decay_paths = _decay_mask(params, self.decay_exclude)

        def upd(path, m, v, p, do_decay):
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * do_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree_util.tree_map_with_path(
            upd, mu, nu, params, decay_paths)
        return updates, AdamState(step=step, mu=mu, nu=nu)


def _decay_mask(params: Any, exclude: Tuple[str, ...]) -> Any:
    def mask(path, p):
        names = [str(getattr(e, "key", "")) for e in path]
        return 0.0 if (names and names[-1] in exclude) or p.ndim <= 1 else 1.0
    return jax.tree_util.tree_map_with_path(mask, params)


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)


# ==========================================================================
# Schedules
# ==========================================================================
def cosine_schedule(peak: float, warmup_steps: int, total_steps: int,
                    floor: float = 0.0) -> Callable[[jax.Array], jax.Array]:
    """Linear warmup → cosine decay to ``floor``."""
    def lr(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, cos)
    return lr


def constant_schedule(value: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.asarray(value, jnp.float32)
