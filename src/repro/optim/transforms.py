"""Composable gradient transforms: clipping + SRR gradient scaling.

The SRR QPEFT rule (paper Eq. 7–9) attenuates gradients along preserved
adapter directions. It is expressed here as a *gradient transform* applied
before the optimizer update, so it composes with AdamW (or anything with
the same (init, update) contract) and stays jittable: the per-rank scale
vectors are precomputed at adapter init and live in the frozen tree.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.qpeft import AdapterParams, AdapterStatic, scale_adapter_grads


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    """Returns (clipped grads, pre-clip norm)."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def srr_grad_transform(statics: Any) -> Callable[[Any], Any]:
    """Transform scaling AdapterParams gradients by their per-rank vectors.

    ``statics`` is a pytree of AdapterStatic aligned with the trainable
    adapter tree (same structure, AdapterStatic leaves where the grads tree
    has AdapterParams leaves); non-adapter leaves pass through unchanged.
    """
    def transform(grads: Any) -> Any:
        def apply(g, s):
            if isinstance(g, AdapterParams) and isinstance(s, AdapterStatic):
                return scale_adapter_grads(g, s)
            return g
        return jax.tree_util.tree_map(
            apply, grads, statics,
            is_leaf=lambda x: isinstance(x, (AdapterParams, AdapterStatic)))
    return transform


def scale_lr_grads_by_key(grads: Any, scales: Any) -> Any:
    """Dict-schema variant used by the model zoo's QPEFT path.

    The trainable tree holds per-layer dicts {"l": (m, r), "r": (r, n)};
    ``scales`` holds matching {"gscale": (r,)} leaves. Gradients on ``l``
    columns / ``r`` rows are multiplied by the per-rank vector.
    """
    def walk(g: Any, s: Any) -> Any:
        if isinstance(g, dict) and "l" in g and "r" in g:
            vec = s["gscale"] if isinstance(s, dict) and "gscale" in s else None
            if vec is None:
                return g
            out = dict(g)
            # broadcast over possible leading (scan/expert) dims
            out["l"] = g["l"] * vec[..., None, :]
            out["r"] = g["r"] * vec[..., :, None]
            return out
        if isinstance(g, dict):
            return {k: walk(v, s.get(k) if isinstance(s, dict) else None)
                    for k, v in g.items()}
        if isinstance(g, (list, tuple)):
            ss = s if isinstance(s, (list, tuple)) else [None] * len(g)
            return type(g)(walk(v, sv) for v, sv in zip(g, ss))
        return g
    return walk(grads, scales)
